"""Quickstart for the unified ``repro.solve`` front-end.

Shows the four ways in: a general-form problem batch, a heterogeneous
problem list (shape-bucketed megabatching), the closed-form hyperbox
path, and backend selection through the registry.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro
from repro import LPProblem, SolveOptions
from repro.core import lp


def main():
    rng = np.random.default_rng(0)

    # 1) General form: minimize c.x s.t. bl <= Ax <= bu, lo <= x <= hi.
    #    (equality rows via bl == bu, free variables via lo = -inf)
    p = LPProblem.make(
        c=[2.0, 1.0, -1.0],
        a=[[1.0, 1.0, 1.0], [1.0, -1.0, 0.0]],
        bl=[3.0, -np.inf],
        bu=[3.0, 2.0],          # first row is an equality: x1+x2+x3 == 3
        lo=[0.0, 0.0, -np.inf],  # x3 is free
        hi=[2.0, np.inf, 1.0],
        maximize=False,
    )
    sol = repro.solve(p)
    print(f"general form: objective={float(sol.objective[0]):.3f}, "
          f"x={np.asarray(sol.x[0]).round(3)}, "
          f"status={lp.STATUS_NAMES[int(sol.status[0])]}")

    # 2) A batch of canonical LPs (the paper's form) still goes straight in.
    batch = lp.random_lp_batch(rng, batch=1000, m=28, n=28, feasible_start=True,
                               dtype=np.float32)
    sol = repro.solve(batch, SolveOptions(rule="lpc"))
    print(f"solved {batch.batch} LPs of size {batch.m}x{batch.n}")
    print(f"  statuses: optimal={int((np.asarray(sol.status)==lp.OPTIMAL).sum())}, "
          f"mean iterations={float(np.asarray(sol.iterations).mean()):.1f}")

    # 3) Heterogeneous list: mixed shapes bucketed into shape-class
    #    megabatches, results scattered back in input order.
    problems = []
    for dim in (5, 12, 28, 5, 12, 5):
        b = lp.random_lp_batch(rng, 1, dim, dim, True, dtype=np.float32)
        problems.append(LPProblem.make(b.c, b.a, bu=b.b))
    sols = repro.solve(problems)
    print(f"heterogeneous list: {len(problems)} LPs in "
          f"{len({(q.m, q.n) for q in problems})} shape classes -> "
          f"objectives {[round(float(s.objective[0]), 3) for s in sols]}")

    # 4) Hyperbox LPs (paper Sec. 6): closed form, millions at a time.
    #    Box-only problems (no general rows) auto-route here too.
    lo, hi, dirs = lp.random_hyperbox_batch(rng, 100_000, 5, dtype=np.float32)
    sol3 = repro.solve_hyperbox(lo, hi, dirs)
    print(f"hyperbox batch: {sol3.objective.shape[0]} LPs solved, "
          f"support[:4]={np.asarray(sol3.objective[:4]).round(3)}")

    # 5) Backend registry: same protocol, different engines.
    #    ("pallas" = VMEM-resident kernels: interpret mode on CPU, Mosaic
    #    on TPU; "pdhg" = first-order restarted PDHG, crossover polishes
    #    its answer to an exact vertex; "reference" = sequential float64
    #    NumPy oracle.)
    small = lp.LPBatch(batch.a[:64], batch.b[:64], batch.c[:64])
    base = repro.solve(small)
    for name in repro.available_backends():
        if name == "xla" or name.endswith("-shared"):
            continue  # shared twins consume SharedLPBatch — demoed below
        opts = SolveOptions(backend=name, crossover=(name == "pdhg"))
        other = repro.solve(small, opts)
        # Compare where both sides report OPTIMAL: iterative backends may
        # honestly return ITER_LIMIT on a few hard rows instead of a value.
        ok = ((np.asarray(other.status) == lp.OPTIMAL)
              & (np.asarray(base.status) == lp.OPTIMAL))
        agree = np.allclose(np.asarray(other.objective)[ok],
                            np.asarray(base.objective)[ok], rtol=1e-4)
        print(f"backend {name!r} agrees with xla: {agree} "
              f"({int(ok.sum())}/{small.batch} rows optimal on both)")

    # 6) Shared-structure batches: ONE constraint matrix, many c/b
    #    variants — the revised-simplex twins store A once and keep only
    #    O(m^2) basis state per LP (support sweeps emit this natively).
    shared = lp.random_shared_lp_batch(rng, 64, 12, 6, feasible_start=True,
                                       dtype=np.float32)
    dense = repro.solve(shared.densify())
    for name in ("xla-shared", "pallas-shared"):
        ssol = repro.solve(shared, SolveOptions(backend=name))
        ok = ((np.asarray(ssol.status) == lp.OPTIMAL)
              & (np.asarray(dense.status) == lp.OPTIMAL))
        agree = np.allclose(np.asarray(ssol.objective)[ok],
                            np.asarray(dense.objective)[ok], rtol=1e-4)
        print(f"backend {name!r} agrees with densified xla: {agree} "
              f"({int(ok.sum())}/{shared.batch} rows optimal on both)")


if __name__ == "__main__":
    main()
