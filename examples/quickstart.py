"""Quickstart: solve a batch of LPs three ways and cross-check.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import lp
from repro.core.solver import BatchedLPSolver


def main():
    rng = np.random.default_rng(0)

    # 1) General LPs: max c.x s.t. Ax <= b, x >= 0  — batched simplex.
    batch = lp.random_lp_batch(rng, batch=1000, m=28, n=28, feasible_start=True,
                               dtype=np.float32)
    solver = BatchedLPSolver(rule="lpc")
    sol = solver.solve(batch)
    print(f"solved {batch.batch} LPs of size {batch.m}x{batch.n}")
    print(f"  statuses: optimal={int((np.asarray(sol.status)==lp.OPTIMAL).sum())}, "
          f"mean iterations={float(np.asarray(sol.iterations).mean()):.1f}")
    print(f"  first objectives: {np.asarray(sol.objective[:4]).round(3)}")

    # 2) Two-phase LPs (infeasible initial basis, like the paper's 2nd class).
    batch2 = lp.random_lp_batch(rng, 500, m=24, n=10, feasible_start=False,
                                dtype=np.float32)
    sol2 = solver.solve(batch2)
    print(f"two-phase batch: optimal={int((np.asarray(sol2.status)==lp.OPTIMAL).sum())}"
          f"/{batch2.batch}")

    # 3) Hyperbox LPs (paper Sec. 6): closed form, millions at a time.
    lo, hi, dirs = lp.random_hyperbox_batch(rng, 100_000, 5, dtype=np.float32)
    sol3 = solver.solve_hyperbox(lo, hi, dirs)
    print(f"hyperbox batch: {sol3.objective.shape[0]} LPs solved, "
          f"support[:4]={np.asarray(sol3.objective[:4]).round(3)}")

    # 4) Pallas-kernel backend (interpret mode on CPU; Mosaic on TPU).
    k_sol = BatchedLPSolver(backend="pallas").solve(
        lp.LPBatch(batch.a[:64], batch.b[:64], batch.c[:64])
    )
    agree = np.allclose(
        np.asarray(k_sol.objective), np.asarray(sol.objective[:64]), rtol=1e-4
    )
    print(f"pallas kernel agrees with XLA path: {agree}")


if __name__ == "__main__":
    main()
