"""End-to-end LM training driver (example c of the deliverables).

Trains a reduced-config model on the synthetic recurrence language for a
few hundred steps with checkpointing — loss should drop well below the
uniform baseline ln(V).  Any of the ten assigned archs is selectable.

  PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m --steps 200
  PYTHONPATH=src python examples/train_lm.py --arch gemma2-2b --steps 100
  (full-size configs are for pods: add --no-reduced at your own peril)
"""

import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--no-reduced", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--seq", str(args.seq),
        "--batch", str(args.batch),
        "--ckpt", args.ckpt,
        "--lr", "1e-3",
    ]
    if not args.no_reduced:
        cmd.append("--reduced")
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
